"""Temperature-smoothed relaxations of the fluid model's hard gates.

The closed loop in ``core.fluid`` / ``core.cc`` is full of hard
nonlinearities — PFC xoff/xon hysteresis, kmin/kmax marking thresholds,
CNP suppression windows, rate clamps.  Each one is a ``jnp.where`` on a
boolean, so ``jax.grad`` through the dt-scan sees zero gradient w.r.t.
every CC constant that only acts through a threshold crossing.

This module provides the smoothing primitives those sites use.  The
contract, enforced by the golden/bitwise suites and the annealing test
in ``tests/test_tune.py``:

  * every softened site is written ``select(tau, soft_expr, hard_expr)``
    where ``hard_expr`` is *literally the pre-existing hard code* — at
    ``tau == 0`` the step is bitwise identical to the hard model;
  * ``tau`` is ``StepParams.temperature``: traced data, so hard sweeps
    and soft tuner rollouts share ONE compiled step (the soft branch is
    a handful of extra elementwise ops, negligible next to the link
    reductions);
  * as ``tau -> 0`` the soft expressions converge pointwise to the hard
    ones (sigmoid gates sharpen to step functions, softplus clamps to
    min/max), so annealed optimisation lands on the hard dynamics.

Gradient hygiene: ``jnp.where`` is a select, not arithmetic — the
untaken branch's value is discarded, and its cotangent is multiplied by
the (0/1) predicate, so the hard branch never pollutes ``jax.grad`` at
``tau > 0``.  Blends (``gate*a + (1-gate)*b``) are only used where both
operands are finite; sites with ``inf`` sentinels (waterfilling grants,
severity mins) select instead of blending, because ``0 * inf = nan``.

Pure ``jnp`` on purpose — ``core.fluid`` imports this module at the
top level, so it must not import anything from ``repro.core``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: Additive floor on sigmoid/softplus widths (guards ``scale == 0``
#: sites; the tau = 0 case is handled by :func:`safe_tau`).
TINY = 1e-30


def safe_tau(tau):
    """``tau`` where positive, 1.0 at ``tau == 0``.

    At temperature zero ``select`` discards the soft branch, but
    ``jax.grad`` still differentiates it: a width of ``0 * scale``
    would put ``x / width`` at +-inf and the backward pass would turn
    the (correctly zero) cotangent into ``0 * inf = nan``.  Evaluating
    the dead branch at tau = 1 keeps every intermediate and every VJP
    finite without changing any tau > 0 value.
    """
    return jnp.where(tau > 0.0, tau, 1.0)


def unit_gate(x, tau, scale):
    """Soft step: ``sigmoid(x / (tau * scale))`` -> ``1[x > 0]`` as tau->0.

    ``scale`` sets the natural units of ``x`` (port-buffer bytes, line
    rate, a CNP window) so one dimensionless ``tau`` smooths every site
    comparably: the transition band is ``O(tau * scale)`` wide.
    """
    return jax.nn.sigmoid(x / (safe_tau(tau) * scale + TINY))


def select(tau, soft_expr, hard_expr):
    """The soft expression at ``tau > 0``, the hard one (bitwise) at 0."""
    return jnp.where(tau > 0.0, soft_expr, hard_expr)


def pick(tau, gate, cond, a, b):
    """Gated choice: hard ``where(cond, a, b)``, soft ``gate*a+(1-gate)*b``.

    ``gate`` is the soft relaxation of the boolean ``cond`` (hard mode
    carries it as an exact 0/1 float).  Operands must be finite — this
    is a blend, not a select.
    """
    return select(tau, gate * a + (1.0 - gate) * b, jnp.where(cond, a, b))


def softplus(x, width):
    """``width * log(1 + exp(x / width))`` -> ``max(x, 0)`` as width->0."""
    return width * jax.nn.softplus(x / (width + TINY))


def clip(x, lo, hi, tau, scale):
    """Two-sided soft clamp -> ``jnp.clip(x, lo, hi)`` bitwise at tau=0.

    Soft form: a softplus hinge at each edge, transition band
    ``O(tau * scale)`` wide.  Monotone in ``x`` and differentiable in
    ``x``, ``lo`` and ``hi``.
    """
    w = safe_tau(tau) * scale + TINY
    soft_lo = lo + softplus(x - lo, w)
    soft_both = hi - softplus(hi - soft_lo, w)
    return select(tau, soft_both, jnp.clip(x, lo, hi))
