"""repro.tune — differentiable + Bayesian CC autotuning.

The fluid model is pure JAX; this package exploits it.  ``soft``
provides temperature-smoothed relaxations of the hard gates in
``core.fluid`` / ``core.cc`` (behind the traced
``StepParams.temperature``), ``objectives`` the scalar/multi-objective
functions (goodput, p99 flow slowdown, Jain fairness, control-traffic
overhead), ``optimizers`` the tuner loops (``GradTuner`` — jax.grad
through the dt-scan on the smoothed model; ``ESTuner`` — antithetic
evolution strategies; ``BOTuner`` — GP/Thompson sampling), and
``pareto`` the ``autotune()`` front-door plus scalarisation sweeps
producing Pareto fronts.

Lazy exports (PEP 562): ``core.fluid`` imports ``repro.tune.soft`` at
module top, so this ``__init__`` must not import ``repro.core``-heavy
submodules eagerly — attribute access resolves them on demand.
"""

from __future__ import annotations

_EXPORTS = {
    "soft": ("repro.tune.soft", None),
    "objectives": ("repro.tune.objectives", None),
    "optimizers": ("repro.tune.optimizers", None),
    "pareto": ("repro.tune.pareto", None),
    "TunableParam": ("repro.tune.optimizers", "TunableParam"),
    "ParamBox": ("repro.tune.optimizers", "ParamBox"),
    "dcqcn_box": ("repro.tune.optimizers", "dcqcn_box"),
    "rev_box": ("repro.tune.optimizers", "rev_box"),
    "TuneProblem": ("repro.tune.optimizers", "TuneProblem"),
    "Evaluator": ("repro.tune.optimizers", "Evaluator"),
    "box_for": ("repro.tune.optimizers", "box_for"),
    "GradTuner": ("repro.tune.optimizers", "GradTuner"),
    "ESTuner": ("repro.tune.optimizers", "ESTuner"),
    "BOTuner": ("repro.tune.optimizers", "BOTuner"),
    "autotune": ("repro.tune.pareto", "autotune"),
    "pareto_autotune": ("repro.tune.pareto", "pareto_autotune"),
    "pareto_front": ("repro.tune.pareto", "pareto_front"),
    "TuneResult": ("repro.tune.pareto", "TuneResult"),
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    try:
        mod_name, attr = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.tune' has no attribute {name!r}") from None
    import importlib

    mod = importlib.import_module(mod_name)
    return mod if attr is None else getattr(mod, attr)
