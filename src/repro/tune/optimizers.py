"""CC parameter tuners: gradient (soft model), ES and BO (hard model).

The pieces:

  * :class:`TunableParam` / :class:`ParamBox` — a bounded, optionally
    log-scaled search box over CC constants.  Each knob names both its
    ``StepParams`` leaves (what a traced rollout reads, e.g.
    ``"mark.cp_kmin"``) and its config paths (what a human sets, e.g.
    ``"dcqcn.kmin"``); ``apply`` swaps tuned values into a ``StepParams``
    pytree inside a trace, ``to_spec`` writes the same values back into
    a frozen ``CCSpec`` and *asserts* the two routes agree through
    ``step_params`` — the box cannot silently tune a different constant
    than it reports.
  * :class:`TuneProblem` / :class:`Evaluator` — one (config, scenario,
    objective) instance.  ``value_and_grad`` differentiates the
    temperature-smoothed rollout (``repro.tune.soft``) through the
    dt-scan — the whole thing is ONE cached executable in
    ``SWEEP_EXEC_CACHE`` (AOT-compiled, keyed like a sweep launch).
    ``hard_values`` scores parameter batches on the exact hard model by
    riding ``Sweep.run`` — the population IS a sweep, so ES/BO
    evaluations vectorise onto the existing one-jit vmap run axis and
    hit the same executable cache.
  * :class:`GradTuner` — Adam (inlined; no external optimiser dep) on
    an unconstrained reparameterisation of the box, ascending
    ``jax.grad`` of the soft objective.
  * :class:`ESTuner` — antithetic evolution strategies on the hard
    model (no smoothing bias, works for the integer-ish knobs gradients
    cannot see).
  * :class:`BOTuner` — Bayesian optimisation: a fixed-hyperparameter
    RBF Gaussian process on the unit box with Thompson-sampling batch
    proposals.

All tuners checkpoint through ``repro.ckpt`` (``ckpt_dir=...``): host
state is float64 numpy and per-iteration randomness is keyed
``default_rng([seed, it])``, so a killed-and-resumed run replays the
exact trajectory of an uninterrupted one (bit-exact, tested).
"""

from __future__ import annotations

import dataclasses
import operator
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.experiments import SWEEP_EXEC_CACHE, ScenarioSpec, Sweep
from repro.core.fluid import (Scenario, check_routing_paths, fluid_step,
                              init_state, scenario_device, step_params)
from repro.core.params import CCConfig, CCSpec
from repro.core.simulator import _resolve_steps, decimating_scan

from . import objectives

# ---------------------------------------------------------------------------
# the search box
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TunableParam:
    """One bounded knob, named on both sides of ``step_params``.

    ``leaves`` are dotted ``StepParams`` paths (``"mark.cp_kmin"``,
    ``"react.rp_g"``, or a top-level field like ``"xoff"``) — what
    ``ParamBox.apply`` overrides inside a traced rollout.
    ``spec_paths`` are the matching dotted config paths
    (``"dcqcn.kmin"``) written by ``to_spec``.  Several paths tune as
    one knob (DCQCN's step marking uses one V for kmin = kmax).
    ``log=True`` searches the decade range geometrically.
    """

    name: str
    leaves: tuple
    spec_paths: tuple
    lo: float
    hi: float
    log: bool = False

    def __post_init__(self):
        if not (0 < self.lo < self.hi) and self.log:
            raise ValueError(f"{self.name}: log scale needs 0 < lo < hi")
        if self.lo >= self.hi:
            raise ValueError(f"{self.name}: empty range [{self.lo}, "
                             f"{self.hi}]")


def _sigmoid(x, xp):
    return 1.0 / (1.0 + xp.exp(-x))


def _replace_many(cfg, updates: dict):
    """All dotted-path writes in one ``dataclasses.replace`` per parent.

    Sequential single-path writes would trip ``__post_init__``
    validation on transient states (e.g. raising kmin above the old
    kmax before kmax is written); batching means validators only ever
    see the final combination.
    """
    direct, nested = {}, {}
    for path, v in updates.items():
        head, _, rest = path.partition(".")
        if rest:
            nested.setdefault(head, {})[rest] = v
        else:
            direct[head] = v
    for head, sub in nested.items():
        direct[head] = _replace_many(getattr(cfg, head), sub)
    return dataclasses.replace(cfg, **direct)


def _get_leaf(par, path: str):
    head, _, rest = path.partition(".")
    v = getattr(par, head)
    return v[rest] if rest else v


def _set_leaf(par, path: str, value):
    head, _, rest = path.partition(".")
    if rest:
        fam = dict(getattr(par, head))
        if rest not in fam:
            raise KeyError(f"StepParams.{head} has no leaf {rest!r} "
                           f"(have {sorted(fam)})")
        fam[rest] = value
        return par._replace(**{head: fam})
    return par._replace(**{head: value})


@dataclasses.dataclass(frozen=True)
class ParamBox:
    """A tuple of :class:`TunableParam` — the tuner's search space.

    Optimisers work in unconstrained theta-space; ``values`` maps theta
    through a sigmoid onto each knob's (lin or log) range, so every
    theta is feasible and bounds never need projection.
    """

    params: tuple

    def __post_init__(self):
        names = [p.name for p in self.params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate knob names in box: {names}")

    @property
    def d(self) -> int:
        return len(self.params)

    @property
    def names(self) -> tuple:
        return tuple(p.name for p in self.params)

    def signature(self) -> tuple:
        """Hashable identity for executable-cache keys."""
        return tuple((p.name, p.leaves, p.spec_paths, p.lo, p.hi, p.log)
                     for p in self.params)

    def values(self, theta, xp=jnp):
        """[d] theta -> [d] physical values (jnp inside traces, np on
        host — same formulas, so host round-trips match the trace)."""
        u = _sigmoid(theta, xp)
        lo = xp.asarray([p.lo for p in self.params], theta.dtype)
        hi = xp.asarray([p.hi for p in self.params], theta.dtype)
        is_log = xp.asarray([p.log for p in self.params], bool)
        lin = lo + (hi - lo) * u
        geo = xp.exp(xp.log(lo) + (xp.log(hi) - xp.log(lo)) * u)
        return xp.where(is_log, geo, lin)

    def apply(self, par, theta):
        """StepParams with this box's leaves overridden from theta."""
        vals = self.values(jnp.asarray(theta, jnp.float32))
        for tp, v in zip(self.params, vals):
            for leaf in tp.leaves:
                _get_leaf(par, leaf)          # raises on a bad path
                par = _set_leaf(par, leaf, v)
        return par

    def encode(self, cfg: "CCConfig | CCSpec") -> np.ndarray:
        """theta [d] f64 whose values reproduce the config's current
        settings (clipped just inside the box)."""
        spec = cfg.to_spec()
        theta = np.zeros(self.d)
        for i, tp in enumerate(self.params):
            v = float(operator.attrgetter(tp.spec_paths[0])(spec))
            if tp.log:
                u = (np.log(max(v, tp.lo)) - np.log(tp.lo)) \
                    / (np.log(tp.hi) - np.log(tp.lo))
            else:
                u = (v - tp.lo) / (tp.hi - tp.lo)
            u = float(np.clip(u, 1e-4, 1 - 1e-4))
            theta[i] = np.log(u / (1 - u))
        return theta

    def to_spec(self, cfg: "CCConfig | CCSpec", theta) -> CCSpec:
        """The config with this theta's values written back.

        Consistency-checked: the spec is flattened through
        ``step_params`` and every tuned ``StepParams`` leaf must equal
        the value ``apply`` would have used — so what a tuner reports
        is provably what its rollouts ran.
        """
        spec = cfg.to_spec()
        vals = self.values(np.asarray(theta, np.float32), xp=np)
        updates = {path: float(v)
                   for tp, v in zip(self.params, vals)
                   for path in tp.spec_paths}
        spec = _replace_many(spec, updates)
        par = step_params(spec)
        for tp, v in zip(self.params, vals):
            for leaf in tp.leaves:
                got = float(np.asarray(_get_leaf(par, leaf)))
                if not np.isclose(got, float(v), rtol=1e-5, atol=0):
                    raise AssertionError(
                        f"box inconsistency: {tp.name}: spec path(s) "
                        f"{tp.spec_paths} produced StepParams leaf "
                        f"{leaf} = {got}, expected {float(v)}")
        return spec


def dcqcn_box() -> ParamBox:
    """The DCQCN knobs the paper's sensitivity analysis walks: the
    marking threshold V (kmin = kmax, step marking), the rate-decrease
    aggressiveness, the alpha gain g and the additive-increase slope."""
    return ParamBox((
        TunableParam("V", ("mark.cp_kmin",),
                     ("dcqcn.kmin", "dcqcn.kmax"), 2e3, 2.56e5, log=True),
        TunableParam("rdf", ("react.rp_rdf",),
                     ("dcqcn.rate_decrease_factor",), 0.05, 1.0),
        TunableParam("g", ("react.rp_g",), ("dcqcn.g",),
                     1.0 / 1024, 0.25, log=True),
        TunableParam("rai", ("react.rp_rai",), ("dcqcn.rai",),
                     1e6, 2e8, log=True),
    ))


def rev_box() -> ParamBox:
    """The paper-scheme (ECP/ENP/ERP) knobs: detection threshold,
    settle fraction, recovery slope and hold-down."""
    return ParamBox((
        TunableParam("thresh", ("mark.ecp_thresh",),
                     ("rev.detect_threshold",), 4e3, 1.28e5, log=True),
        TunableParam("settle", ("react.erp_settle",),
                     ("rev.erp_settle",), 0.5, 1.0),
        TunableParam("rai", ("react.erp_rai",),
                     ("rev.erp_rai",), 1e11, 5e13, log=True),
        TunableParam("hold", ("react.erp_hold",),
                     ("rev.erp_hold",), 5e-6, 5e-4, log=True),
    ))


def box_for(cfg: "CCConfig | CCSpec") -> ParamBox:
    """Default box for a config, keyed on its reaction stage."""
    reaction = cfg.to_spec().reaction
    boxes = {"rp": dcqcn_box, "erp": rev_box}
    if reaction not in boxes:
        raise ValueError(
            f"no default ParamBox for reaction {reaction!r}; pass an "
            f"explicit box= (have defaults for {sorted(boxes)})")
    return boxes[reaction]()


# ---------------------------------------------------------------------------
# the problem + its evaluators
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TuneProblem:
    """One tuning instance: which config, on which workload, scored
    how, over which knobs."""

    cfg: "CCConfig | CCSpec"
    scenario: "Scenario | ScenarioSpec"
    objective: "str | dict | Callable" = "default"
    box: ParamBox = None
    n_steps: int = 2000
    trace_every: int = 50

    def __post_init__(self):
        if self.box is None:
            self.box = box_for(self.cfg)


class _TraceShim:
    """Host-side stand-in for the stacked TraceSample (objectives only
    read ``.ctrl``)."""

    def __init__(self, ctrl):
        self.ctrl = np.asarray(ctrl, np.float32)


class Evaluator:
    """Compiled evaluation paths for one :class:`TuneProblem`."""

    def __init__(self, problem: TuneProblem):
        self.problem = problem
        self.box = problem.box
        cfg = problem.cfg
        self.spec: CCSpec = cfg.to_spec()
        scn = problem.scenario
        if isinstance(scn, ScenarioSpec):
            scn = scn.build(cfg)
        check_routing_paths(cfg, scn)
        self.scn: Scenario = scn
        self.sd = scenario_device(scn)
        self.st0 = init_state(scn, cfg)
        self.par0 = step_params(cfg)
        self.n_samples, self.k = _resolve_steps(
            cfg, problem.n_steps, problem.trace_every)
        self.dt = float(cfg.sim.dt)
        self.n_sw = scn.n_switches
        self.horizon = self.n_samples * self.k * self.dt
        self.ctx = objectives.make_ctx(
            scn, cfg.link.line_rate, self.horizon, self.dt)
        self.obj_fn, self.obj_sig = objectives.resolve(problem.objective)
        self._vag = None

    # -- soft path: one AOT-compiled value_and_grad -------------------------

    def _vag_exec(self):
        if self._vag is not None:
            return self._vag
        n_samples, k, dt, n_sw = (self.n_samples, self.k, self.dt,
                                  self.n_sw)
        box, obj_fn = self.box, self.obj_fn
        args = (jnp.zeros((box.d,), jnp.float32),
                jnp.asarray(0.0, jnp.float32),
                self.st0, self.sd, self.par0, self.ctx)
        leaves, treedef = jax.tree.flatten(args)
        shapes = tuple((tuple(x.shape), x.dtype.name) for x in leaves)
        key = ("tune_vag", box.signature(), self.obj_sig,
               n_samples, k, dt, n_sw, treedef, shapes)

        def build():
            def loss(theta, tau, st0, sd, par0, ctx):
                par = box.apply(par0, theta)
                par = par._replace(
                    temperature=jnp.asarray(tau, jnp.float32))
                step = lambda s: fluid_step(
                    s, sd, par, dt=dt, n_switches=n_sw,
                    reduce="fused", dense_rows=0)
                final, tr = decimating_scan(step, st0, n_samples, k, dt)
                return obj_fn(final, tr, ctx)

            return jax.jit(jax.value_and_grad(loss)) \
                .lower(*args).compile()

        self._vag = SWEEP_EXEC_CACHE.get_or_build(key, build)
        return self._vag

    def value_and_grad(self, theta, temperature: float):
        """(soft objective, d(objective)/d(theta)) at one theta.

        ``temperature`` is traced data — every call reuses one cached
        executable; 0.0 evaluates the exact hard model (with the
        gradient of its soft limit)."""
        v, g = self._vag_exec()(
            jnp.asarray(theta, jnp.float32),
            jnp.asarray(temperature, jnp.float32),
            self.st0, self.sd, self.par0, self.ctx)
        return float(v), np.asarray(g, np.float64)

    # -- hard path: populations ride the Sweep engine -----------------------

    def hard_values(self, thetas) -> np.ndarray:
        """[P] exact hard-model objective for a theta batch.

        Each theta becomes a ``CCSpec`` (consistency-checked) and the
        batch runs as ONE ``Sweep`` launch — the population shares the
        sweep executable cache, so repeated generations of the same
        shape never recompile.  Values come from the same objective
        function the soft path uses, applied to the hard traces.
        """
        thetas = np.atleast_2d(np.asarray(thetas, np.float64))
        points = [(f"t{i}", self.box.to_spec(self.spec, th), self.scn)
                  for i, th in enumerate(thetas)]
        res = Sweep(points).run(
            n_steps=self.problem.n_steps, trace_every=self.k)
        return np.asarray([self.hard_objective(res[i])
                           for i in range(len(thetas))])

    def hard_objective(self, sim_result) -> float:
        """The tuner objective evaluated on a finished hard run."""
        val = self.obj_fn(sim_result.final,
                          _TraceShim(sim_result.ctrl), self.ctx)
        return float(np.asarray(val))


# ---------------------------------------------------------------------------
# checkpoint plumbing (repro.ckpt; host f64 state, bit-exact resume)
# ---------------------------------------------------------------------------


def _ckpt_save(ckpt_dir, it, state: dict):
    from repro.ckpt import save_checkpoint
    save_checkpoint(ckpt_dir, it, state, extra={"it": it})


def _ckpt_load(ckpt_dir):
    """(state, it) from the latest committed checkpoint, or (None, 0)."""
    from repro.ckpt import latest_step, load_checkpoint
    if ckpt_dir is None or latest_step(ckpt_dir) is None:
        return None, 0
    tree, extra = load_checkpoint(ckpt_dir)
    return tree, int(extra["it"])


@dataclasses.dataclass
class TuneTrace:
    """Everything a tuner evaluated: [n, d] thetas, [n] objective
    values (soft for :class:`GradTuner`, hard for ES/BO) and metadata.
    ``best`` is the argmax theta — candidates for the *decision* should
    still be re-scored on the hard model (``pareto.autotune`` does)."""

    theta: np.ndarray
    value: np.ndarray
    meta: dict

    @property
    def best(self) -> np.ndarray:
        return self.theta[int(np.argmax(self.value))]


# ---------------------------------------------------------------------------
# tuners
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GradTuner:
    """Adam ascent on the temperature-smoothed objective.

    The gradient flows through the full dt-scan (soft gates, see
    ``repro.tune.soft``); Adam is inlined (bias-corrected, standard
    constants) so the tuner has no optimiser dependency.  ``anneal``
    decays the temperature geometrically to ``temperature_final`` over
    the run — late iterations score an almost-hard model.
    """

    iters: int = 40
    lr: float = 0.15
    temperature: float = 0.06
    temperature_final: float = None     # None = constant temperature
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8

    def _tau(self, it: int) -> float:
        if self.temperature_final is None or self.iters <= 1:
            return self.temperature
        frac = it / (self.iters - 1)
        return float(self.temperature
                     * (self.temperature_final / self.temperature) ** frac)

    def run(self, problem: TuneProblem, *, theta0=None, seed: int = 0,
            ckpt_dir: str = None, ckpt_every: int = 0) -> TuneTrace:
        ev = problem if isinstance(problem, Evaluator) else \
            Evaluator(problem)
        d = ev.box.d
        theta = np.asarray(theta0, np.float64) if theta0 is not None \
            else ev.box.encode(ev.spec)
        m, v = np.zeros(d), np.zeros(d)
        hist_t, hist_v = [], []
        state, start = _ckpt_load(ckpt_dir)
        if state is not None:
            theta, m, v = (np.asarray(state[k])
                           for k in ("theta", "m", "v"))
            hist_t = list(np.asarray(state["hist_t"]))
            hist_v = list(np.asarray(state["hist_v"]))
        for it in range(start, self.iters):
            val, g = ev.value_and_grad(theta, self._tau(it))
            hist_t.append(theta.copy())
            hist_v.append(val)
            m = self.beta1 * m + (1 - self.beta1) * g
            v = self.beta2 * v + (1 - self.beta2) * g * g
            mh = m / (1 - self.beta1 ** (it + 1))
            vh = v / (1 - self.beta2 ** (it + 1))
            theta = theta + self.lr * mh / (np.sqrt(vh) + self.eps)
            if ckpt_dir and ckpt_every and (it + 1) % ckpt_every == 0:
                _ckpt_save(ckpt_dir, it + 1, {
                    "theta": theta, "m": m, "v": v,
                    "hist_t": np.asarray(hist_t),
                    "hist_v": np.asarray(hist_v)})
        # score the final iterate so the trajectory includes it
        val, _ = ev.value_and_grad(theta, self._tau(self.iters - 1))
        hist_t.append(theta.copy())
        hist_v.append(val)
        return TuneTrace(np.asarray(hist_t), np.asarray(hist_v),
                         {"method": "grad", "iters": self.iters,
                          "temperature": self.temperature})


@dataclasses.dataclass
class ESTuner:
    """Antithetic evolution strategies on the exact hard model.

    Each generation draws ``pop/2`` Gaussian directions, scores the
    +/- pair batch as ONE sweep launch, and ascends the score-weighted
    direction average (normalised by the generation's value spread).
    Per-generation randomness is keyed ``default_rng([seed, it])`` so a
    checkpoint resume replays the identical trajectory.
    """

    iters: int = 20
    pop: int = 16
    sigma: float = 0.25
    lr: float = 0.3

    def run(self, problem: TuneProblem, *, theta0=None, seed: int = 0,
            ckpt_dir: str = None, ckpt_every: int = 0) -> TuneTrace:
        if self.pop % 2:
            raise ValueError("ESTuner.pop must be even (antithetic)")
        ev = problem if isinstance(problem, Evaluator) else \
            Evaluator(problem)
        d = ev.box.d
        half = self.pop // 2
        theta = np.asarray(theta0, np.float64) if theta0 is not None \
            else ev.box.encode(ev.spec)
        hist_t, hist_v = [], []
        state, start = _ckpt_load(ckpt_dir)
        if state is not None:
            theta = np.asarray(state["theta"])
            hist_t = list(np.asarray(state["hist_t"]))
            hist_v = list(np.asarray(state["hist_v"]))
        for it in range(start, self.iters):
            rng = np.random.default_rng([seed, it])
            eps = rng.standard_normal((half, d))
            cand = np.concatenate(
                [theta + self.sigma * eps, theta - self.sigma * eps])
            vals = ev.hard_values(cand)
            hist_t.extend(cand)
            hist_v.extend(vals)
            adv = vals[:half] - vals[half:]
            scale = max(float(vals.std()), 1e-9)
            g = (adv[:, None] * eps).sum(0) / (self.pop * self.sigma
                                               * scale)
            theta = theta + self.lr * g
            if ckpt_dir and ckpt_every and (it + 1) % ckpt_every == 0:
                _ckpt_save(ckpt_dir, it + 1, {
                    "theta": theta,
                    "hist_t": np.asarray(hist_t),
                    "hist_v": np.asarray(hist_v)})
        final_val = ev.hard_values(theta[None])[0]
        hist_t.append(theta.copy())
        hist_v.append(final_val)
        return TuneTrace(np.asarray(hist_t), np.asarray(hist_v),
                         {"method": "es", "iters": self.iters,
                          "pop": self.pop, "sigma": self.sigma})


def _rbf(a: np.ndarray, b: np.ndarray, ls: float) -> np.ndarray:
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return np.exp(-0.5 * d2 / ls ** 2)


@dataclasses.dataclass
class BOTuner:
    """Thompson-sampling Bayesian optimisation on the unit box.

    A fixed-hyperparameter RBF GP (lengthscale on the [0, 1]^d encoded
    box, values standardised per fit) is cheap, dependency-free and
    deterministic; each iteration draws ``q`` joint posterior samples
    at ``cand`` uniform candidates and evaluates the batch of argmaxes
    as one sweep launch.  Exploration comes from posterior variance,
    not a tuned acquisition.
    """

    iters: int = 12
    init: int = 6
    q: int = 2
    cand: int = 256
    lengthscale: float = 0.35
    noise: float = 1e-4

    @staticmethod
    def _logit(u: np.ndarray) -> np.ndarray:
        u = np.clip(u, 1e-4, 1 - 1e-4)
        return np.log(u / (1 - u))

    def _propose(self, X, y, rng) -> np.ndarray:
        """[<=q, d] unit-box batch from joint Thompson samples."""
        C = rng.uniform(size=(self.cand, X.shape[1]))
        mu, sd = y.mean(), max(float(y.std()), 1e-9)
        ys = (y - mu) / sd
        K = _rbf(X, X, self.lengthscale) + self.noise * np.eye(len(X))
        L = np.linalg.cholesky(K)
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, ys))
        Kc = _rbf(C, X, self.lengthscale)
        mean = Kc @ alpha
        v = np.linalg.solve(L, Kc.T)
        cov = _rbf(C, C, self.lengthscale) - v.T @ v
        Lc = np.linalg.cholesky(cov + 1e-8 * np.eye(self.cand))
        z = rng.standard_normal((self.cand, self.q))
        picks = np.unique(np.argmax(mean[:, None] + Lc @ z, axis=0))
        return C[picks]

    def run(self, problem: TuneProblem, *, theta0=None, seed: int = 0,
            ckpt_dir: str = None, ckpt_every: int = 0) -> TuneTrace:
        ev = problem if isinstance(problem, Evaluator) else \
            Evaluator(problem)
        d = ev.box.d
        state, start = _ckpt_load(ckpt_dir)
        if state is not None:
            X = np.asarray(state["X"])
            y = np.asarray(state["y"])
        else:
            rng = np.random.default_rng([seed, 0])
            u0 = _sigmoid(np.asarray(
                theta0 if theta0 is not None else ev.box.encode(ev.spec),
                np.float64), np)
            X = np.concatenate(
                [u0[None], rng.uniform(size=(max(self.init - 1, 0), d))])
            y = ev.hard_values(self._logit(X))
        for it in range(start + 1, self.iters + 1):
            rng = np.random.default_rng([seed, it])
            U = self._propose(X, y, rng)
            vals = ev.hard_values(self._logit(U))
            X = np.concatenate([X, U])
            y = np.concatenate([y, vals])
            if ckpt_dir and ckpt_every and it % ckpt_every == 0:
                _ckpt_save(ckpt_dir, it, {"X": X, "y": y})
        return TuneTrace(self._logit(X), y,
                         {"method": "bo", "iters": self.iters,
                          "q": self.q, "cand": self.cand})


TUNERS = {"grad": GradTuner, "es": ESTuner, "bo": BOTuner}
