"""Fault-tolerance walkthrough: train, 'crash', resume bit-exactly, then
restore the same checkpoint onto a *different* mesh (elastic scaling).

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import shutil
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.data import DataConfig
from repro.models import ModelConfig
from repro.models.layers import init_params
from repro.models.transformer import param_defs
from repro.optim import AdamWConfig
from repro.train.loop import TrainLoopConfig, train_loop
from repro.train.step import StepConfig, init_train_state, make_train_step

CKPT = "/tmp/repro_elastic"


def main():
    shutil.rmtree(CKPT, ignore_errors=True)
    cfg = ModelConfig(name="elastic-demo", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=512)
    params = init_params(param_defs(cfg), 0, jnp.float32)
    sc = StepConfig(opt=AdamWConfig(lr=1e-3), warmup_steps=5,
                    total_steps=100)
    state = init_train_state(cfg, params, sc)
    step = jax.jit(make_train_step(cfg, sc))
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4,
                      kind="markov")

    # --- phase 1: train 25 steps, checkpoint every 10, then "crash" ----
    out1 = train_loop(step, state, data,
                      TrainLoopConfig(total_steps=25, ckpt_dir=CKPT,
                                      ckpt_every=10))
    print(f"phase1: reached step {out1['final_step']} "
          f"(last committed ckpt: step 20); simulating crash...")

    # --- phase 2: restart; loop auto-resumes from step 20 --------------
    out2 = train_loop(step, state, data,
                      TrainLoopConfig(total_steps=40, ckpt_dir=CKPT,
                                      ckpt_every=10))
    print(f"phase2: auto-resumed and reached step {out2['final_step']} "
          f"({len(out2['losses'])} new steps — exact data continuation)")
    assert out2["final_step"] == 40

    # --- phase 3: elastic restore onto an explicit 1-device mesh -------
    from repro.ckpt import load_checkpoint
    from repro.train.loop import NT_REGISTRY
    mesh = jax.make_mesh((1,), ("data",))
    flat_restored, extra = load_checkpoint(CKPT, nt_registry=NT_REGISTRY)
    resharded = jax.tree.map(
        lambda a: jax.device_put(
            a, NamedSharding(mesh, PartitionSpec())), flat_restored.params)
    print(f"phase3: restored step-{extra['data_step']} params onto mesh "
          f"{dict(mesh.shape)} — {len(jax.tree.leaves(resharded))} arrays "
          f"resharded")
    # verify restored == in-memory final params
    same = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()),
        resharded, out2["state"].params)
    print(f"max |restored - live| = {max(jax.tree.leaves(same)):.2e}")
    print("elastic restart demo complete.")


if __name__ == "__main__":
    main()
