"""Quickstart: train a small LM end-to-end on CPU with the full stack —
config registry, synthetic data pipeline, AdamW + cosine schedule,
microbatch accumulation, int8+EF compressed gradients, async atomic
checkpoints, straggler detection, exact resume.

    PYTHONPATH=src python examples/quickstart.py [--steps 300] [--big]

``--big`` trains a ~100M-param model (slow on CPU but the real thing);
the default is a ~3M-param model that converges visibly in ~2 minutes.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.data import DataConfig
from repro.models import ModelConfig
from repro.models.layers import init_params
from repro.models.transformer import param_defs
from repro.optim import AdamWConfig
from repro.train.loop import TrainLoopConfig, train_loop
from repro.train.step import StepConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--big", action="store_true",
                    help="~100M params instead of ~3M")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_quickstart")
    args = ap.parse_args()

    if args.big:
        cfg = ModelConfig(name="quickstart-100m", n_layers=12, d_model=768,
                          n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
                          vocab=32768)
    else:
        cfg = ModelConfig(name="quickstart-3m", n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=2, head_dim=32, d_ff=512,
                          vocab=1024)
    print(f"model: {cfg.name}  params ~{cfg.param_count()/1e6:.1f}M")

    params = init_params(param_defs(cfg), seed=0, dtype=jnp.float32)
    sc = StepConfig(opt=AdamWConfig(lr=1e-2, weight_decay=0.01),
                    microbatches=2, compress_grads=True,
                    warmup_steps=20, total_steps=args.steps)
    state = init_train_state(cfg, params, sc)
    step = jax.jit(make_train_step(cfg, sc))

    data = DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8,
                      kind="markov")
    loop = TrainLoopConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                           ckpt_every=100, log_every=20)

    def on_metrics(s, m):
        print(f"step {s:4d}  loss {float(m['loss']):.4f}  "
              f"lr {float(m['lr']):.2e}  "
              f"gnorm {float(m['grad_norm']):.2f}  "
              f"{m['step_time']*1e3:.0f} ms")

    out = train_loop(step, state, data, loop, on_metrics=on_metrics)
    print(f"\ndone: steps={out['final_step']} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"(stragglers: {out['stragglers']})")
    print(f"checkpoints in {args.ckpt_dir} — rerun to resume exactly.")


if __name__ == "__main__":
    main()
