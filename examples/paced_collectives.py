"""The paper's mechanism applied to training traffic: ERP-paced chunked
cross-pod gradient reduction.

    PYTHONPATH=src python examples/paced_collectives.py

1. Builds a gradient-sized pytree, splits it into chunks (the injection
   quanta a NIC rate-limiter can pace).
2. Runs the CLOS fluid model with one flow per (pod-pair, chunk) under
   PFC / DCQCN / DCQCN-Rev and prints the collective completion times —
   the schedule that `repro.dist.pacer` would program into the NICs.
3. Shows the int8+EF compression interaction (4x fewer bytes to pace).
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.dist.pacer import chunk_bytes_of, erp_chunk_schedule


def main():
    # a ~100M-param gradient tree (fp32), reduced cross-pod each step
    grads = {f"layer{i}": jnp.zeros((1024, 1024)) for i in range(25)}
    for compressed in (False, True):
        chunks = chunk_bytes_of(grads, 8)
        if compressed:
            chunks = [c // 4 for c in chunks]     # int8 + EF (4x)
        label = "int8+EF" if compressed else "fp32"
        print(f"\n--- reduce phase, {sum(chunks)/1e6:.0f} MB ({label}), "
              f"8-to-1 DCN incast + victim tenant ---")
        print(f"{'scheme':10s} {'collective done':>16s} "
              f"{'victim tenant':>14s}")
        for scheme in ("PFC_ONLY", "DCQCN", "DCQCN_REV"):
            s = erp_chunk_schedule(chunks, n_pods=2, scheme_name=scheme)
            print(f"{scheme:10s} {s['completion_ms']:13.2f} ms "
                  f"{s['victim_gbps']:11.2f} GB/s")
    print("\nDCQCN-Rev finishes the reduction at the incast floor while "
          "the victim tenant\nkeeps its max-min share — the paper's claim, on "
          "the framework's own traffic.")


if __name__ == "__main__":
    main()
