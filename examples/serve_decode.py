"""Batched serving demo: continuous batching over a trained-ish model.

    PYTHONPATH=src python examples/serve_decode.py [--arch mixtral-8x22b]

Uses the reduced (smoke) config of the chosen architecture, exercises
prefill -> slot-based continuous batching -> ragged completion, and
reports tokens/second.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_smoke_config
from repro.models import transformer
from repro.models.layers import init_params
from repro.serve import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b",
                    choices=[a for a in ARCHS
                             if a not in ("whisper-base", "internvl2-26b")])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--eos", type=int, default=1,
                    help="EOS token id (ragged completion -> slot refill)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    print(f"serving reduced {args.arch}: {cfg.n_layers}L d{cfg.d_model} "
          f"(~{cfg.param_count()/1e6:.1f}M params)")
    params = init_params(transformer.param_defs(cfg), 0, jnp.float32)
    eng = ServingEngine(cfg, params,
                        ServeConfig(batch_slots=4, max_len=128,
                                    temperature=0.8, eos_token=args.eos))

    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(2, cfg.vocab, size=rng.randint(3, 9)))
               for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new_tokens=args.new_tokens)
    dt = time.perf_counter() - t0
    n_tok = sum(len(o) for o in outs)
    for i, o in enumerate(outs[:4]):
        print(f"req{i}: prompt={prompts[i][:6]}... -> {o[:12]}...")
    s = eng.stats
    print(f"\n{args.requests} requests, {n_tok} tokens in {dt:.1f}s "
          f"({n_tok/dt:.1f} tok/s on CPU)")
    print(f"continuous batching: {s['prefills']} joint prefill(s), "
          f"{s['refills']} mid-flight slot refill(s), "
          f"{s['decode_steps']} decode steps "
          f"(a finished slot hands its grid row to the next request "
          f"without stopping the batch)")


if __name__ == "__main__":
    main()
