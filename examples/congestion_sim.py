"""Reproduce the paper's §II evaluation interactively.

    PYTHONPATH=src python examples/congestion_sim.py [--roll 0|1]
        [--scheme PFC_ONLY|DCQCN|DCQCN_REV|all] [--volume-mb 9.375]

Prints the per-flow bandwidth table (Fig. 3), aggregate plateaus (Fig. 2)
and equal-work completion times; writes timelines to artifacts/paper/.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (CCScheme, PAPER_CONFIG, PAPER_FLOW_NAMES,
                        paper_incast, paper_incast_volume, run)


def show(scheme: CCScheme, roll: int, volume_mb: float):
    cfg = PAPER_CONFIG.replace(scheme=scheme)
    rw = run(paper_incast(cfg, roll=roll), cfg, n_steps=14000)
    rv = run(paper_incast_volume(cfg, roll=roll,
                                 volume_bytes=volume_mb * 1e6),
             cfg, n_steps=18000)
    thr = rw.mean_throughput_while_active() / 1e9
    ct = rv.completion_times() * 1e3
    print(f"\n=== {scheme.name} (roll={roll}) ===")
    print(f"{'flow':<12s} {'GB/s':>8s} {'done ms':>9s} {'marks':>7s}")
    marks = rw.marked.sum(0)
    for i, name in enumerate(PAPER_FLOW_NAMES):
        print(f"{name:<12s} {thr[i]:8.3f} {ct[i]:9.2f} {marks[i]:7d}")
    print(f"{'AGGREGATE':<12s} {thr.sum():8.3f} {np.nanmax(ct):9.2f}"
          f"   peak-queue {rw.max_q.max()/1e3:.0f} KB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--roll", type=int, default=0, choices=(0, 1),
                    help="0: shared-wire (Fig3 HoL); 1: disjoint (Fig2)")
    ap.add_argument("--scheme", default="all",
                    choices=[s.name for s in CCScheme] + ["all"])
    ap.add_argument("--volume-mb", type=float, default=9.375)
    args = ap.parse_args()

    schemes = (list(CCScheme) if args.scheme == "all"
               else [CCScheme[args.scheme]])
    for s in schemes:
        show(s, args.roll, args.volume_mb)
    print("\nExpected (paper §II): DCQCN-Rev completes first, PFC second, "
          "DCQCN last;\nvictim unharmed only under DCQCN-Rev; 25 GB/s "
          "aggregate in the disjoint wiring.")


if __name__ == "__main__":
    main()
