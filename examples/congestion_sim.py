"""Reproduce the paper's §II evaluation interactively.

    PYTHONPATH=src python examples/congestion_sim.py [--roll 0|1]
        [--scheme PFC_ONLY|DCQCN|DCQCN_REV|all] [--volume-mb 9.375]

All requested (scheme x window/equal-work) runs execute as ONE batched
Sweep launch (see repro.core.experiments).  Prints the per-flow
bandwidth table (Fig. 3), aggregate plateaus (Fig. 2) and equal-work
completion times.
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import (CCScheme, PAPER_CONFIG, PAPER_FLOW_NAMES,
                        ScenarioSpec, Sweep)


def show(res, scheme: CCScheme, roll: int):
    rw = res[f"{scheme.name}/window"]
    rv = res[f"{scheme.name}/volume"]
    thr = rw.mean_throughput_while_active() / 1e9
    ct = rv.completion_times() * 1e3
    print(f"\n=== {scheme.name} (roll={roll}) ===")
    print(f"{'flow':<12s} {'GB/s':>8s} {'done ms':>9s} {'marks':>7s}")
    marks = rw.marked.sum(0)
    for i, name in enumerate(PAPER_FLOW_NAMES):
        print(f"{name:<12s} {thr[i]:8.3f} {ct[i]:9.2f} {marks[i]:7d}")
    print(f"{'AGGREGATE':<12s} {thr.sum():8.3f} {np.nanmax(ct):9.2f}"
          f"   peak-queue {rw.max_q.max()/1e3:.0f} KB")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--roll", type=int, default=0, choices=(0, 1),
                    help="0: shared-wire (Fig3 HoL); 1: disjoint (Fig2)")
    ap.add_argument("--scheme", default="all",
                    choices=[s.name for s in CCScheme] + ["all"])
    ap.add_argument("--volume-mb", type=float, default=9.375)
    args = ap.parse_args()

    schemes = (list(CCScheme) if args.scheme == "all"
               else [CCScheme[args.scheme]])
    sweep = Sweep.grid(
        configs={s.name: PAPER_CONFIG.replace(scheme=s) for s in schemes},
        scenarios={
            "window": ScenarioSpec.paper_incast(roll=args.roll),
            "volume": ScenarioSpec.paper_incast_volume(
                roll=args.roll, volume_bytes=args.volume_mb * 1e6),
        })
    res = sweep.run(n_steps=18000)      # one compile, one device launch
    for s in schemes:
        show(res, s, args.roll)
    print("\nExpected (paper §II): DCQCN-Rev completes first, PFC second, "
          "DCQCN last;\nvictim unharmed only under DCQCN-Rev; 25 GB/s "
          "aggregate in the disjoint wiring.")


if __name__ == "__main__":
    main()
