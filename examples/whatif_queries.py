"""What-if CC queries: the simulator as a throttled, cache-warm service.

    PYTHONPATH=src python examples/whatif_queries.py

Asks a stream of "what if?" questions — different CC stacks and
parameter tweaks on different incast storms of one pod — through
``CCQueryEngine``.  The first query on the pod shape pays XLA
compilation once; every later query (any CC scheme, any constants, any
workload in the same flow bucket) coalesces into warm micro-batches on
the vmap run axis.  A fifth tenant then bursts past its token-bucket
rate and gets explicit ``Throttled`` outcomes instead of queueing
unboundedly.
"""

import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import CCSpec, ScenarioSpec
from repro.serve.whatif import (AdmissionConfig, Admitted, CCQueryEngine,
                                EngineConfig, Throttled, WhatIfQuery)


def main():
    eng = CCQueryEngine(EngineConfig(
        max_batch=8,
        admission=AdmissionConfig(rate=100.0, burst=64, max_queue=128)))

    # the quickstart: one question, one answer
    r = eng.ask(WhatIfQuery(cfg=CCSpec(reaction="erp"),
                            scenario=ScenarioSpec.incast(4),
                            n_steps=4000, label="erp/incast4"))
    print(f"[{r.label}] aggregate "
          f"{r.result.summary()['aggregate_gbps']:.2f} GB/s, peak queue "
          f"{r.result.summary()['peak_queue_kb']:.0f} kB "
          f"(latency {r.latency_s:.2f}s, compiled={r.compiled})")

    # a stream of follow-ups: schemes x tunings x workloads, all warm
    stacks = {
        "dcqcn": CCSpec(marking="cp", notification="np", reaction="rp"),
        "swift": CCSpec(reaction="swift"),
        "rev": CCSpec(),
        "rev-settle0.9": CCSpec().replace(rev=dataclasses.replace(
            CCSpec().rev, erp_settle=0.9)),
    }
    tickets = []
    for name, cfg in stacks.items():
        for storm in (4, 6, 7):
            out = eng.submit(WhatIfQuery(
                cfg=cfg, scenario=ScenarioSpec.incast(storm),
                n_steps=4000, label=f"{name}/incast{storm}",
                tenant="explorer"))
            assert isinstance(out, Admitted)
            tickets.append(out.ticket)
    eng.drain()
    print(f"\n{'query':<22}{'agg GB/s':>10}{'peakQ kB':>10}{'marks':>8}")
    for t in tickets:
        qr = eng.result(t)
        s = qr.result.summary()
        print(f"{qr.label:<22}{s['aggregate_gbps']:>10.2f}"
              f"{s['peak_queue_kb']:>10.0f}{s['marks']:>8}")

    # the noisy neighbour: over-rate burst -> explicit Throttled
    greedy = CCQueryEngine(EngineConfig(admission=AdmissionConfig(
        rate=5.0, burst=4, max_queue=16)))
    outcomes = [greedy.submit(WhatIfQuery(
        cfg=CCSpec(), scenario=ScenarioSpec.incast(4), n_steps=1000,
        tenant="greedy")) for _ in range(10)]
    n_throttled = sum(isinstance(o, Throttled) for o in outcomes)
    retry = next(o.retry_after for o in outcomes
                 if isinstance(o, Throttled))
    print(f"\nburst of 10 at rate 5/s, burst 4: "
          f"{10 - n_throttled} admitted, {n_throttled} throttled "
          f"(retry_after {retry:.2f}s) — back-pressure is explicit, "
          f"the queue never grows unboundedly")

    m = eng.metrics()
    print(f"\nserving metrics: {m['queries']} queries in {m['batches']} "
          f"micro-batches (occupancy {m['mean_occupancy']:.2f}), "
          f"cache {m['exec_cache']['hits']}h/{m['exec_cache']['misses']}m "
          f"hit_rate={m['exec_cache']['hit_rate']:.2f}, "
          f"compile {m['compile_s']:.1f}s vs run {m['run_s']:.1f}s, "
          f"p50 {m['latency_s']['p50']:.2f}s p99 "
          f"{m['latency_s']['p99']:.2f}s")


if __name__ == "__main__":
    main()
